"""Plan/apply Aggregator API: shim equivalence, capabilities, transforms.

The acceptance bar for the api_redesign: the deprecated entry points
(``gar.aggregate``, ``tree_aggregate``, ``RobustAggregator``) must be
bitwise-identical to the registry path, for all seven GARs.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import RobustConfig
from repro.core import api, gar
from repro.core.robust import RobustAggregator, tree_aggregate

KEY = jax.random.key(0)
N, F, D = 15, 3, 48
RNG = np.random.default_rng(11)
ALL_GARS = sorted(api.available_gars())


def _stack():
    G = RNG.normal(size=(N, D)).astype(np.float32)
    G[:F] *= 30.0
    return jnp.asarray(G)


def _tree(G):
    return {"a": G[:, :20].reshape(N, 4, 5), "b": {"c": G[:, 20:]}}


@pytest.mark.parametrize("name", ALL_GARS)
def test_flat_shim_bitwise_identical(name):
    G = _stack()
    old = np.asarray(gar.aggregate(G, F, name))
    new = np.asarray(api.aggregate_matrix(G, F, name))
    agg = api.get_aggregator(name)
    direct = np.asarray(agg(G, F))
    np.testing.assert_array_equal(old, new)
    np.testing.assert_array_equal(old, direct)


@pytest.mark.parametrize("name", ALL_GARS)
def test_registry_matches_raw_primitives(name):
    """Non-circular anchor: the registry path must agree with the raw rule
    functions in core/gar.py (independent implementations) up to fp
    reassociation — catches behaviour drift the delegation shims cannot."""
    G = _stack()
    raw = np.asarray(gar.GARS[name](G, F))
    reg = np.asarray(api.aggregate_matrix(G, F, name))
    scale = max(1.0, np.abs(raw).max())
    np.testing.assert_allclose(reg, raw, rtol=0, atol=1e-5 * scale)


@pytest.mark.parametrize("name", ALL_GARS)
def test_tree_shim_bitwise_identical(name):
    tree = _tree(_stack())
    old = tree_aggregate(tree, F, name)
    agg = api.get_aggregator(name)
    stats = api.compute_stats(tree, F, needs_dists=agg.needs_dists)
    new = agg.apply(agg.plan(stats), tree)
    for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ALL_GARS)
def test_robust_aggregator_bitwise_identical(name):
    tree = _tree(_stack())
    cfg = RobustConfig(n_workers=N, f=F, gar=name)
    old = RobustAggregator(cfg)(tree)
    new = api.aggregate_tree(tree, F, name)
    for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_shapes_are_static_and_d_free():
    """Plans depend only on (n, f) — never on d (the O(d) split)."""
    G = _stack()
    stats = api.compute_stats(G, F, needs_dists=True)
    assert stats.dists.shape == (N, N)
    plan = api.get_aggregator("multi_bulyan").plan(stats)
    theta = N - 2 * F - 2
    assert plan.kind == "bulyan"
    assert plan.w_ext.shape == (theta, N)
    assert plan.w_agr.shape == (theta, N)
    assert plan.beta == theta - 2 * F
    kplan = api.get_aggregator("multi_krum").plan(stats)
    assert kplan.kind == "weighted" and kplan.weights.shape == (N,)
    np.testing.assert_allclose(float(jnp.sum(kplan.weights)), 1.0, rtol=1e-6)


def test_capability_flags():
    assert not api.get_aggregator("average").needs_dists
    assert not api.get_aggregator("median").needs_dists
    assert api.get_aggregator("krum").needs_dists
    assert api.get_aggregator("multi_bulyan").needs_dists
    assert api.get_aggregator("median").coordinate_local
    assert not api.get_aggregator("multi_krum").coordinate_local
    assert api.get_aggregator("multi_bulyan").min_n(3) == 15
    assert api.get_aggregator("krum").min_n(3) == 9


def test_registry_rejects_unknown_and_validates_min_n():
    with pytest.raises(KeyError):
        api.get_aggregator("nope")
    with pytest.raises(ValueError, match="4f\\+3"):
        api.aggregate_matrix(jnp.zeros((10, 4)), 2, "multi_bulyan")


def test_robust_config_validate():
    RobustConfig(n_workers=15, f=3, gar="multi_bulyan").validate()
    with pytest.raises(ValueError, match="4f\\+3"):
        RobustConfig(n_workers=14, f=3, gar="bulyan")
    with pytest.raises(ValueError, match="2f\\+3"):
        RobustConfig(n_workers=8, f=3, gar="krum")
    with pytest.raises(ValueError, match="unknown GAR"):
        RobustConfig(n_workers=8, f=1, gar="typo_rule")
    with pytest.raises(ValueError):
        RobustConfig(n_workers=4, f=4, gar="average")


def test_register_custom_gar_roundtrip():
    """Adding a rule is one decorated class — the simulator-registry story."""

    @api.register_gar
    class FirstWorker(api.Aggregator):
        name = "first_worker_test_only"

        def plan(self, stats):
            w = jnp.zeros((stats.n,), jnp.float32).at[0].set(1.0)
            return api.AggPlan(kind="weighted", n=stats.n, f=stats.f,
                               weights=w)

    try:
        G = _stack()
        out = api.aggregate_matrix(G, F, "first_worker_test_only")
        np.testing.assert_allclose(np.asarray(out), np.asarray(G[0]),
                                   rtol=1e-6)
        # and it is immediately usable from a RobustConfig
        RobustConfig(n_workers=3, f=1, gar="first_worker_test_only")
    finally:
        api.REGISTRY.pop("first_worker_test_only")


# ------------------------------------------------------------- transforms
def test_clip_by_norm_bounds_every_worker():
    G = _stack()
    out, _ = api.ClipByNorm(max_norm=1.0)(G)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(norms <= 1.0 + 1e-5)
    # direction preserved
    i = N - 1
    cos = np.dot(np.asarray(out)[i], np.asarray(G)[i]) / (
        np.linalg.norm(np.asarray(out)[i]) * np.linalg.norm(np.asarray(G)[i]))
    assert cos > 0.999


def test_worker_momentum_accumulates():
    t = api.WorkerMomentum(beta=0.5)
    g = {"w": jnp.ones((N, 4))}
    state = t.init(g)
    out1, state = t(g, state=state)
    out2, state = t(g, state=state)
    np.testing.assert_allclose(np.asarray(out1["w"]), 1.0)
    np.testing.assert_allclose(np.asarray(out2["w"]), 1.5)


def test_nn_mix_pulls_outlier_toward_cloud():
    G = np.ones((N, D), np.float32) + 0.01 * RNG.normal(size=(N, D)).astype(np.float32)
    G[0] = 100.0
    tr = api.NearestNeighborMix(k=3)
    stats = api.compute_stats(jnp.asarray(G), F, needs_dists=True)
    out, _ = tr(jnp.asarray(G), stats=stats)
    # honest workers mix only with honest neighbours (outlier is far)
    assert np.abs(np.asarray(out)[1:] - 1.0).max() < 0.1


def test_transform_pipeline_in_robust_aggregator():
    cfg = RobustConfig(n_workers=N, f=F, gar="multi_bulyan")
    agg = RobustAggregator(cfg, transforms=(api.ClipByNorm(max_norm=5.0),))
    tree = _tree(_stack())
    out, states = agg(tree)
    assert states == (None,)
    for leaf in jax.tree.leaves(out):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_stateful_transform_through_train_step():
    """Worker momentum threads its state through dist.make_train_step."""
    from repro.configs.base import ArchConfig
    from repro.data import lm_batches
    from repro.dist import init_train_state, make_train_step, split_workers
    from repro import models as MD
    from repro.optim import constant, sgd

    cfg = ArchConfig(name="t-mom", family="dense", n_layers=2, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)
    rcfg = RobustConfig(n_workers=11, f=2, gar="multi_krum")
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.0)
    transforms = (api.WorkerMomentum(beta=0.9),)
    state = init_train_state(opt, params, transforms, n_workers=11)
    step = jax.jit(make_train_step(cfg, rcfg, opt, constant(0.05),
                                   chunk_q=8, attack="sign_flip",
                                   transforms=transforms))
    it = lm_batches(cfg.vocab_size, 22, 8, seed=5)
    losses = []
    for i in range(6):
        b = split_workers(next(it), 11)
        params, state, m = step(params, state, b, jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    assert len(state.tstates) == 1
    # momentum state is live (nonzero) and training stays finite
    assert any(float(jnp.max(jnp.abs(x))) > 0
               for x in jax.tree.leaves(state.tstates[0]))
    assert np.isfinite(losses[-1])
