"""repro.analysis: lint rules, jaxpr contract auditors, VMEM estimator.

Every rule/auditor must trip on its known-bad fixture AND pass on the
real repo — a gate that is vacuous in either direction is worse than no
gate.  The VMEM estimator is held to the committed BENCH_agg_time.json
grid: it must launch on the exact two-level tile pair the kernels use,
keep the d=1e6 point macro-resident (cliff closed), and its crossover
prediction must stay consistent with the measured dispatch table.
"""
import json
import os

import pytest
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import jaxpr_audit as JA
from repro.analysis import lint, vmem
from repro.core import api

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures_analysis")
LINT_PATHS = [os.path.join(REPO, p)
              for p in ("src", "benchmarks", "examples")]

KEY = jax.random.key(0)


def _mesh11():
    """A 1×1 (data, model) mesh: tracing needs axis *names*, not devices."""
    return jax.make_mesh((1, 1), ("data", "model"))


# ================================================================= lint
@pytest.mark.parametrize("rule", sorted(lint.RULES))
def test_lint_rule_trips_on_fixture(rule):
    path = os.path.join(FIXTURES, f"bad_{rule.lower()}.py")
    found = {v.rule for v in lint.lint_paths([path])}
    assert rule in found, f"{rule} did not fire on {path}: {found}"


def test_lint_fixture_hits_are_only_the_advertised_rule():
    # R000 shadows everything (unparseable), R001's import-time calls are
    # the only violations in its file, etc. — no rule may false-positive
    # on another rule's fixture beyond its own advertised id
    for rule in sorted(lint.RULES):
        path = os.path.join(FIXTURES, f"bad_{rule.lower()}.py")
        for v in lint.lint_paths([path]):
            assert v.rule == rule, (rule, str(v))


def test_repo_lints_clean():
    violations = lint.lint_paths(LINT_PATHS)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_violation_render_and_json():
    (v,) = lint.lint_source("import jax.numpy as jnp\nX = jnp.zeros(3)\n",
                            "mod.py")
    assert v.rule == "R001" and v.line == 2
    assert "mod.py:2" in str(v)
    assert v.to_json()["rule"] == "R001"


# ========================================================== jaxpr audits
@pytest.fixture(scope="module")
def grads():
    return {"w": jax.random.normal(KEY, (11, 8, 32)),
            "b": jax.random.normal(jax.random.key(1), (11, 16))}


def test_c201_proven_on_repo_apply(grads):
    ctx = api.MeshContext.for_mesh(_mesh11())
    res = JA.audit_apply_gather(grads, f=2, mesh_ctx=ctx)
    assert res.ok, res.violations


def test_c201_trips_on_model_axis_gather():
    mesh = _mesh11()

    def body(x):
        return jax.lax.all_gather(x, ("data", "model"), axis=0, tiled=True)

    fn = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(None),
                   check_rep=False)
    closed = jax.make_jaxpr(fn)(jnp.zeros((8, 16)))
    violations, gathers = JA.gather_violations(
        closed, allowed=10 ** 9, model_axis="model")
    assert gathers == 1 and violations, violations


def test_c201_trips_on_oversized_gather():
    mesh = _mesh11()

    def body(x):
        return jax.lax.all_gather(x, "data", axis=0, tiled=True)

    fn = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P(None),
                   check_rep=False)
    closed = jax.make_jaxpr(fn)(jnp.zeros((8, 16)))
    violations, _ = JA.gather_violations(
        closed, allowed=8 * 16 - 1, model_axis="model")
    assert violations and "exceeds" in violations[0]


def test_c202_proven_on_repo_encoded_path(grads):
    ctx = api.MeshContext.for_mesh(_mesh11())
    res = JA.audit_decode_invariant(grads, f=2, mesh_ctx=ctx)
    assert res.ok, res.violations


def test_c202_trips_on_replicated_decode():
    # the forbidden §9 shape: dequantize the full (n, d) payload at the
    # top level (outside any shard body)
    def replicated(p, m):
        return (p.astype(jnp.float32) * m[:, None]).sum(0)

    closed = jax.make_jaxpr(replicated)(
        jnp.zeros((8, 16), jnp.int8), jnp.ones((8,)))
    violations, decodes = JA.full_stack_decodes(closed, 8,
                                                require_in_shard=True)
    assert decodes == 1 and violations, violations


def test_c203_proven_on_repo_and_self_test(grads):
    ctx = api.MeshContext.for_mesh(_mesh11())
    closed = jax.make_jaxpr(
        lambda g: api.aggregate_tree(g, 2, "multi_bulyan",
                                     mesh_ctx=ctx))(grads)
    assert JA.audit_tp_seam(closed).ok
    # the self-test *is* the negative fixture: it must report "proven",
    # which certifies the auditor tripped on the synthetic tp flatten
    assert JA.tp_seam_self_test().ok


def test_c203_trips_on_constrained_flatten():
    mesh = _mesh11()

    def bad(x):
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None, None, "model")))
        return x.reshape(x.shape[0], -1)

    closed = jax.make_jaxpr(bad)(jnp.zeros((8, 4, 64)))
    res = JA.audit_tp_seam(closed)
    assert not res.ok and "§10" in res.violations[0]


def test_c204_proven_on_jitted_aggregate(grads):
    fn = jax.jit(lambda g: api.aggregate_tree(g, 2, "multi_bulyan"))
    res = JA.audit_single_compile(fn, lambda: (grads,), label="agg")
    assert res.ok, res.violations


def test_c204_trips_on_retracing_fn():
    calls = [0]

    def make_args():
        calls[0] += 1
        return (jnp.ones((4,)), float(calls[0]))   # new static each call

    fn = jax.jit(lambda x, s: x.sum() + s, static_argnums=(1,))
    res = JA.audit_single_compile(fn, make_args, label="retracey")
    assert not res.ok and res.violations


def test_c205_proven_on_hier_path():
    grads21 = {"w": jax.random.normal(KEY, (21, 8, 32))}
    res = JA.audit_hier_decode(grads21, f=1, spec="g=7")
    assert res.ok, res.violations


def test_c205_trips_on_full_stack_decode():
    def bad(p, m):
        return (p.astype(jnp.float32) * m[:, None])[:7].mean(0)

    closed = jax.make_jaxpr(bad)(
        jnp.zeros((21, 16), jnp.int8), jnp.ones((21,)))
    violations, _ = JA.full_stack_decodes(closed, 21,
                                          require_in_shard=False)
    assert violations


# ================================================================= vmem
@pytest.fixture(scope="module")
def bench():
    with open(os.path.join(REPO, "BENCH_agg_time.json")) as fh:
        payload = json.load(fh)
    return payload.get("results", payload)


def test_vmem_matches_tile_policy_at_grid_points():
    # the estimator must live on the exact (d_tile, macro_tile) pair the
    # wrappers launch with — the shared two-level policy, called not
    # re-derived
    from repro.kernels import ops
    for n, d in ((11, 4096), (15, 100_000), (15, 1_000_000)):
        est = vmem.estimate_fused_select(n, d)
        n_pad = n + (-n) % 8
        theta = n - 2 * vmem.f_for_bench(n) - 2
        want = ops.fused_select_tiles(n_pad, d, theta)
        assert (est.d_tile, est.macro_tile) == want
        assert est.macro_tile % est.d_tile == 0
        assert est.windows == est.macro_tile // est.d_tile
        assert est.vmem_bytes <= est.vmem_budget   # chosen pair must fit
        stats = vmem.estimate_pairwise_stats(n, d)
        assert (stats.d_tile, stats.macro_tile) == ops._stats_tiles(n_pad, d)
        assert stats.vmem_bytes <= stats.vmem_budget


def test_vmem_stats_inner_tile_is_the_pr2_autotune_value():
    # the stats inner window is bitwise-pinned to the single-level
    # autotune tile (tile boundaries ARE the accumulation order); only
    # the macro block is new
    from repro.kernels import ops
    for n, d in ((15, 100_000), (15, 1_000_000)):
        n_pad = n + (-n) % 8
        fixed = n_pad * (n_pad + 8) * 4
        est = vmem.estimate_pairwise_stats(n, d)
        assert est.d_tile == ops.autotune_d_tile(n_pad, d,
                                                 fixed_bytes=fixed)


def test_vmem_two_level_closes_the_d1e6_cliff():
    # the deep launch must tile (over_budget), fit per macro step, and
    # run a multi-window macro block that cuts the outer grid depth well
    # below the single-level d_tile grid (the retired cliff regime)
    est = vmem.estimate_fused_select(15, 1_000_000)
    assert est.over_budget and not est.tile_over_budget, est
    assert est.macro_tile > est.d_tile and est.windows > 1, est
    single_level_steps = -(-1_000_000 // est.d_tile)
    assert est.grid_steps * 4 <= single_level_steps, est
    # the residual weight re-read term is amortised over the macro block:
    # read traffic stays within 2% of one clean pass over the stack
    one_pass = 16 * est.grid_steps * est.macro_tile * 4
    assert est.hbm_read_bytes <= 1.02 * one_pass, est


def test_vmem_crossover_calibrated_vs_dispatch_table():
    for n in (11, 15):
        x = vmem.predicted_crossover(n)
        assert x["calibrated"], x
        # the refreshed table has no measured loss: one-sided calibration
        # — the model must predict the win extends past the frontier
        if x["censored"]:
            assert x["ratio"] >= 1.0, x
        else:
            assert 0.5 <= x["ratio"] <= 2.0, x


def test_vmem_traffic_linearity_holds_on_committed_bench(bench):
    diag = vmem.diagnose_traffic_linearity(bench)
    assert diag["holds"], diag
    deepest = [p for p in diag["points"] if p["deepest"]]
    assert deepest, diag
    for p in deepest:
        # the deepest-d point of every n sustains >= half the peak
        # measured bytes/us of that n — cost stays linear in traffic
        assert p["throughput_vs_peak"] >= 0.5, p


def test_vmem_other_kernels_estimable():
    for kernel in ("pairwise_stats", "dequant_stats"):
        est = vmem.estimate(kernel, 15, 100_000)
        assert est.grid_steps >= 1 and est.hbm_read_bytes > 0
    bf16 = vmem.estimate_dequant_stats(15, 100_000, dtype="bfloat16")
    i8 = vmem.estimate_dequant_stats(15, 100_000, dtype="int8")
    assert bf16.hbm_read_bytes > i8.hbm_read_bytes
    with pytest.raises(ValueError):
        vmem.estimate("warp_drive", 15, 4096)
    with pytest.raises(ValueError):
        vmem.estimate_fused_select(15, 4096, d_tile=256, macro_tile=384)
