"""Unit tests for the paper's analytic quantities."""
import math

import pytest

from repro.core import theory


def test_eta_formula():
    # η(n,f) = sqrt(2 (n - f + (f·m + f²(m+1)) / (n-2f-2))), m = n-f-2
    n, f = 15, 3
    m = n - f - 2
    expect = math.sqrt(2 * (n - f + (f * m + f * f * (m + 1)) / (n - 2 * f - 2)))
    assert theory.eta(n, f) == pytest.approx(expect)


def test_eta_no_byzantine():
    # f=0: η = sqrt(2n) — pure sampling-noise cone
    assert theory.eta(10, 0) == pytest.approx(math.sqrt(20))


def test_eta_invalid():
    with pytest.raises(ValueError):
        theory.eta(8, 3)  # n - 2f - 2 = 0


def test_slowdowns():
    assert theory.multi_krum_slowdown(15, 3) == pytest.approx(10 / 15)
    assert theory.multi_bulyan_slowdown(15, 3) == pytest.approx(7 / 15)
    # f << n: slowdown -> 1 (the paper's headline)
    assert theory.multi_bulyan_slowdown(1000, 3) > 0.99


def test_variance_condition_monotone_in_sigma():
    ok = theory.variance_condition(15, 3, 64, sigma=0.01, g_norm=1.0)
    bad = theory.variance_condition(15, 3, 64, sigma=10.0, g_norm=1.0)
    assert ok and not bad


def test_min_workers():
    assert theory.min_workers("multi_bulyan", 3) == 15
    assert theory.min_workers("multi_krum", 3) == 9
    assert theory.min_workers("trimmed_mean", 3) == 7
    assert theory.min_workers("average", 3) == 1


def test_empirical_sigma():
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    G = rng.normal(scale=2.0, size=(64, 1000)).astype(np.float32)
    est = theory.empirical_sigma(jnp.asarray(G))
    assert est == pytest.approx(2.0, rel=0.1)
