"""Serving-path correctness: prefill + decode ≡ full forward, per family."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import models as MD
from repro.dist.serving import generate

from helpers import reduced_cfg

KEY = jax.random.key(0)
SEQ, BATCH = 16, 2
# decode-vs-forward tolerance: bf16 cache roundtrip + differing summation
# order (mamba associative vs sequential scan) — relative to logit scale ~5
TOL = 5e-2


def _extended(cfg, b, new_tok):
    b2 = dict(b)
    b2["tokens"] = jnp.concatenate([b["tokens"], new_tok[:, None]], axis=1)
    return b2


@pytest.mark.parametrize("name", ["nemotron-4-15b", "qwen2-1.5b",
                                  "chatglm3-6b", "qwen3-moe-30b-a3b",
                                  "falcon-mamba-7b", "jamba-1.5-large-398b",
                                  "internvl2-1b", "whisper-tiny"])
@pytest.mark.parametrize("window", [0, 8])
def test_prefill_decode_matches_forward(name, window):
    cfg = reduced_cfg(name)
    if window and cfg.family in ("ssm",):
        pytest.skip("window is attention-only")
    params = MD.init_model(KEY, cfg)
    b = MD.make_batch(cfg, "prefill", BATCH, SEQ, key=KEY)
    last, cache = MD.prefill_fn(params, cfg, b, chunk_q=SEQ, window=window)
    full = MD.forward_fn(params, cfg, b, chunk_q=SEQ, logits_tail=1,
                         window=window)[:, -1]
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full, np.float32), atol=TOL, rtol=0)
    # two decode steps against growing forward
    pos = SEQ
    cur = b
    for step in range(2):
        tok = jax.random.randint(jax.random.fold_in(KEY, step), (BATCH,), 0,
                                 cfg.vocab_size)
        cur = _extended(cfg, cur, tok)
        want = MD.forward_fn(params, cfg, cur, chunk_q=1, logits_tail=1,
                             window=window)[:, -1]
        got, cache = MD.decode_fn(params, cfg, tok, cache,
                                  jnp.int32(pos + step), window=window)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL, rtol=0)


def test_ring_buffer_matches_full_under_window():
    """Sliding-window ring cache == recomputing windowed attention fully,
    beyond the wrap-around point."""
    cfg = reduced_cfg("qwen2.5-32b")
    params = MD.init_model(KEY, cfg)
    W = 8
    b = MD.make_batch(cfg, "prefill", 1, 12, key=KEY)
    _, cache = MD.prefill_fn(params, cfg, b, chunk_q=12, window=W)
    cur = b
    for step in range(6):  # crosses the ring wrap at pos >= W
        tok = jax.random.randint(jax.random.fold_in(KEY, 100 + step), (1,), 0,
                                 cfg.vocab_size)
        cur = _extended(cfg, cur, tok)
        want = MD.forward_fn(params, cfg, cur, chunk_q=1, logits_tail=1,
                             window=W)[:, -1]
        got, cache = MD.decode_fn(params, cfg, tok, cache,
                                  jnp.int32(12 + step), window=W)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL, rtol=0)


@pytest.mark.parametrize("name", ["chatglm3-6b", "falcon-mamba-7b",
                                  "whisper-tiny", "internvl2-1b"])
def test_generate_shapes(name):
    cfg = reduced_cfg(name)
    params = MD.init_model(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size, jnp.int32)
    extra = {}
    if cfg.is_encdec:
        extra["frames"] = jax.random.normal(
            KEY, (2, cfg.n_frames, cfg.d_model), dtype=jnp.bfloat16)
    if cfg.n_patches:
        extra["prefix_embeds"] = jax.random.normal(
            KEY, (2, cfg.n_patches, cfg.d_model), dtype=jnp.bfloat16)
    out = generate(params, cfg, prompt, 5, chunk_q=8,
                   extra_batch=extra or None)
    assert out.shape == (2, 5)
    assert out.dtype == jnp.int32
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


@pytest.mark.parametrize("name", ["chatglm3-6b", "qwen2-1.5b",
                                  "jamba-1.5-large-398b", "whisper-tiny"])
@pytest.mark.parametrize("chunks", [2, 4])
def test_chunked_decode_attention_exact(name, chunks):
    """Flash-style chunk-local partial softmax (§Perf #13) must equal the
    plain full-cache decode path exactly (same fp32 math, reordered)."""
    cfg = reduced_cfg(name)
    params = MD.init_model(KEY, cfg)
    b = MD.make_batch(cfg, "prefill", 2, 16, key=KEY)
    _, cache = MD.prefill_fn(params, cfg, b, chunk_q=16, cache_len=32)
    tok = jax.random.randint(jax.random.key(5), (2,), 0, cfg.vocab_size)
    l1, _ = MD.decode_fn(params, cfg, tok, cache, jnp.int32(16), seq_chunks=1)
    l2, _ = MD.decode_fn(params, cfg, tok, cache, jnp.int32(16),
                         seq_chunks=chunks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=2e-2, rtol=0)


def test_greedy_generation_deterministic():
    cfg = reduced_cfg("qwen2-1.5b")
    params = MD.init_model(KEY, cfg)
    prompt = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size, jnp.int32)
    a = generate(params, cfg, prompt, 6, chunk_q=8)
    b = generate(params, cfg, prompt, 6, chunk_q=8)
    assert np.array_equal(np.asarray(a), np.asarray(b))
