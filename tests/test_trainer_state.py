"""The unified TrainerState pytree: round-trips, checkpoint migration,
slot-presence contracts (PR-5 satellite)."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.dist import TrainerState, as_trainer_state, init_train_state
from repro.optim import sgd
from repro.sim.engine import LEGACY_STATE_ALIASES

KEY = jax.random.key(0)
PARAMS = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
          "b": jnp.ones((3,), jnp.bfloat16)}


def _state(**kw):
    return init_train_state(sgd(momentum=0.9), PARAMS, **kw)


# ------------------------------------------------------------ round trip
def test_flatten_unflatten_round_trip():
    st = _state()
    leaves, treedef = jax.tree.flatten(st)
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, TrainerState)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_empty_slots_flatten_to_zero_leaves():
    plain = _state()
    assert plain.tstates == () and plain.astate is None \
        and plain.cres is None
    # exactly the OptState leaves — the container itself costs nothing
    assert len(jax.tree.leaves(plain)) == len(jax.tree.leaves(plain.opt))


def test_coercion_accepts_bare_opt_state():
    opt = sgd(momentum=0.9)
    st = as_trainer_state(opt.init(PARAMS))
    assert isinstance(st, TrainerState)
    assert as_trainer_state(st) is st
    with pytest.raises(TypeError, match="TrainerState"):
        as_trainer_state({"opt": 1})


# --------------------------------------------------------- slot contracts
def test_ef_residual_slot_present_iff_codec_has_ef():
    assert _state().cres is None
    assert _state(n_workers=11, codec="bf16").cres is None
    assert _state(n_workers=11, codec="qsgd:bits=8").cres is None
    st = _state(n_workers=11, codec="topk:frac=0.1,ef=1")
    assert st.cres is not None
    for leaf, p in zip(jax.tree.leaves(st.cres), jax.tree.leaves(PARAMS)):
        assert leaf.shape == (11,) + p.shape


def test_adaptive_attack_fills_astate():
    st = _state(n_workers=11, attack="adaptive_lie", attack_f=2)
    assert st.astate is not None
    assert _state().astate is None


def test_stateful_transform_fills_tstates():
    from repro.core.api import WorkerMomentum
    st = _state(transforms=(WorkerMomentum(),), n_workers=11)
    assert len(st.tstates) == 1 and st.tstates[0] is not None


# ------------------------------------------------------------ checkpoints
def test_checkpoint_round_trip_current_layout(tmp_path):
    st = _state(n_workers=7, codec="topk:frac=0.1,ef=1")
    save(str(tmp_path), 5, {"params": PARAMS, "state": st})
    loaded = restore(str(tmp_path), 5, {"params": PARAMS, "state": st})
    assert isinstance(loaded["state"], TrainerState)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_migration_from_pr3_era_layout(tmp_path):
    """A PR-3/PR-4-era checkpoint stored the state components as top-level
    keys (opt / tstates / cres); the legacy aliases restore it into the
    TrainerState layout bit-for-bit."""
    st = _state(n_workers=7, codec="topk:frac=0.1,ef=1")
    # write the old layout exactly as the old engine did
    save(str(tmp_path), 9, {"params": PARAMS, "opt": st.opt,
                            "tstates": st.tstates, "cres": st.cres})
    like = {"params": PARAMS, "state": st}
    with pytest.raises(KeyError, match="missing key"):
        restore(str(tmp_path), 9, like)
    loaded = restore(str(tmp_path), 9, like,
                     key_aliases=LEGACY_STATE_ALIASES)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(PARAMS["w"]))


def test_alias_never_shadows_canonical_key(tmp_path):
    """When both layouts exist, the canonical key wins."""
    st = _state()
    stale = jax.tree.map(lambda x: x * 0 - 1.0, st)
    save(str(tmp_path), 3, {"state": st, "opt": stale.opt})
    loaded = restore(str(tmp_path), 3, {"state": st},
                     key_aliases=LEGACY_STATE_ALIASES)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- engine integration
def test_engine_resume_reads_pr5_checkpoint(tmp_path):
    """Phase-boundary checkpoint/resume through the engine keeps working
    on the TrainerState layout (bit-exact tail replay is asserted by
    tests/test_sim.py; here: the layout round-trips through run_campaign)."""
    from repro.sim import run_campaign
    from repro.sim.scenario import AttackPhase, AttackSchedule, Scenario
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name="ts-t", family="dense", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)
    sched = AttackSchedule(phases=(AttackPhase(steps=2, attack="none"),
                                   AttackPhase(steps=2, attack="sign_flip")))
    sc = Scenario(name="ts", arch=cfg, n_workers=7, f=1, gar="multi_bulyan",
                  schedule=sched, per_worker_batch=1, seq=8)
    ckpt = os.path.join(str(tmp_path), "ck")
    full = run_campaign(sc, ckpt_dir=ckpt)
    resumed = run_campaign(sc, ckpt_dir=ckpt, resume=True)
    assert resumed.start_step == sched.total_steps
    assert full.trace["loss"].shape[0] == sched.total_steps
