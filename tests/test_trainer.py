"""Trainer integration: learning, byzantine defence, streaming equivalence."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig, RobustConfig, SSMConfig, HybridConfig
from repro.data import lm_batches
from repro.dist import (init_train_state, inject_byzantine,
                        make_train_step, split_workers)
from repro.dist.streaming import make_streaming_train_step
from repro import models as MD
from repro.optim import sgd, constant

KEY = jax.random.key(0)
N, F = 12, 2

DENSE = ArchConfig(name="t-dense", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   qkv_bias=True)
HYB = ArchConfig(name="t-hyb", family="hybrid", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                 moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, every=2),
                 ssm=SSMConfig(dt_rank=8),
                 hybrid=HybridConfig(period=2, attn_index=1))


def _run(cfg, gar, attack, steps=14, lr=0.05, trainer="stacked", scope="block"):
    rcfg = RobustConfig(n_workers=N, f=F, gar=gar)
    params = MD.init_model(KEY, cfg)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    if trainer == "stacked":
        fn = make_train_step(cfg, rcfg, opt, constant(lr), chunk_q=16,
                             attack=attack)
    else:
        fn = make_streaming_train_step(cfg, rcfg, opt, constant(lr),
                                       scope=scope, chunk_q=16, attack=attack)
    step = jax.jit(fn)
    it = lm_batches(cfg.vocab_size, N * 2, 16, seed=3)
    losses = []
    for i in range(steps):
        b = split_workers(next(it), N)
        params, state, m = step(params, state, b, jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_no_attack():
    losses = _run(DENSE, "multi_bulyan", "none")
    assert losses[-1] < losses[0] - 0.1, losses


def test_multibulyan_survives_inf_attack_averaging_does_not():
    robust = _run(DENSE, "multi_bulyan", "inf")
    assert np.isfinite(robust[-1]) and robust[-1] < robust[0] + 0.1, robust
    broken = _run(DENSE, "average", "inf")
    assert (not np.isfinite(broken[-1])) or broken[-1] > robust[-1] + 0.5, \
        (broken, robust)


def test_krum_family_survives_lie_attack():
    for gar in ("multi_krum", "multi_bulyan"):
        losses = _run(DENSE, gar, "little_is_enough")
        assert np.isfinite(losses[-1]) and losses[-1] < losses[0] + 0.2, \
            (gar, losses)


def test_streaming_global_exact_vs_stacked():
    rcfg = RobustConfig(n_workers=N, f=F, gar="multi_bulyan")
    params = MD.init_model(KEY, HYB)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    b = split_workers(next(lm_batches(HYB.vocab_size, N * 2, 16)), N)
    p1, _, _ = jax.jit(make_train_step(
        HYB, rcfg, opt, constant(0.05), chunk_q=16))(params, state, b, KEY)
    p2, _, _ = jax.jit(make_streaming_train_step(
        HYB, rcfg, opt, constant(0.05), scope="global", chunk_q=16))(
            params, state, b, KEY)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=0, atol=5e-5)


def test_streaming_block_learns_under_attack():
    losses = _run(DENSE, "multi_bulyan", "sign_flip", trainer="stream",
                  scope="block")
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0] + 0.1, losses


def test_inject_byzantine_shapes_and_rows():
    grads = {"w": jnp.ones((N, 3, 4)), "b": jnp.zeros((N, 5))}
    out = inject_byzantine(grads, F, "sign_flip", KEY)
    assert jax.tree.map(lambda x: x.shape, out) == \
        jax.tree.map(lambda x: x.shape, grads)
    # correct rows untouched
    np.testing.assert_array_equal(np.asarray(out["w"][F:]),
                                  np.asarray(grads["w"][F:]))
    # byzantine rows replaced (negated mean of correct = -1)
    np.testing.assert_allclose(np.asarray(out["w"][:F]), -1.0)


def test_stacked_trainer_validates_out_of_band_n():
    """Regression: a batch split into fewer workers than RobustConfig
    promised must fail loudly in the step, not aggregate garbage.  Uses a
    rule whose plan does NOT self-validate — the trainer's own
    aggregator.validate(stats.n, stats.f) call is the only guard."""
    from repro.core import api

    @api.register_gar
    class _NoSelfCheck(api.Aggregator):
        name = "_test_no_self_check"
        min_n_formula = "2f+3"

        @staticmethod
        def min_n(f):
            return 2 * f + 3

        def plan(self, stats):
            return api.AggPlan(kind="mean", n=stats.n, f=stats.f)

    try:
        rcfg = RobustConfig(n_workers=N, f=F, gar="_test_no_self_check")
        params = MD.init_model(KEY, DENSE)
        opt = sgd(momentum=0.0)
        state = init_train_state(opt, params)
        step = jax.jit(make_train_step(DENSE, rcfg, opt, constant(0.01),
                                       chunk_q=16))
        n_oob = 2 * F + 2                      # < min_n, bypasses RobustConfig
        b = split_workers(next(lm_batches(DENSE.vocab_size, n_oob * 2, 16)),
                          n_oob)
        with pytest.raises(ValueError, match="requires n >="):
            step(params, state, b, KEY)
    finally:
        api.REGISTRY.pop("_test_no_self_check")


def test_robust_serve_step_fuses_replica_logits():
    """n replica ensemble decode: GAR consensus over per-replica logits,
    resilient to f corrupted replicas (fused Pallas apply path)."""
    from repro.dist.serving import make_robust_serve_step

    n, f = 7, 1
    rcfg = RobustConfig(n_workers=n, f=f, gar="multi_bulyan",
                        use_pallas=True)
    cfg = DENSE
    batch, seq = 2, 8
    params = MD.init_model(KEY, cfg)
    stacked_params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)
    b = {"tokens": jnp.zeros((batch, seq), jnp.int32)}
    _, cache = MD.prefill_fn(params, cfg, b, chunk_q=seq, cache_len=seq + 2)
    caches = jax.tree.map(
        lambda c: jnp.broadcast_to(c[None], (n,) + c.shape), cache)
    # corrupt replica 0's lm head: its logits become wild outliers
    stacked_params["lm_head"]["w"] = \
        stacked_params["lm_head"]["w"].at[0].mul(1e4)
    step = jax.jit(make_robust_serve_step(cfg, rcfg))
    tok = jnp.zeros((batch,), jnp.int32)
    fused, _ = step(stacked_params, caches, tok, jnp.int32(seq))
    assert fused.shape == (batch, cfg.vocab_size)
    # consensus must stay within the honest replicas' logit range
    per_rep, _ = jax.vmap(
        lambda p, c: MD.decode_fn(p, cfg, tok, c, jnp.int32(seq))
    )(stacked_params, caches)
    honest = np.asarray(per_rep, np.float32)[1:]
    assert np.abs(np.asarray(fused, np.float32)).max() <= \
        np.abs(honest).max() + 1e-3


def test_per_worker_losses_reported():
    rcfg = RobustConfig(n_workers=N, f=F, gar="median")
    params = MD.init_model(KEY, DENSE)
    opt = sgd(momentum=0.0)
    state = init_train_state(opt, params)
    step = jax.jit(make_train_step(DENSE, rcfg, opt, constant(0.01), chunk_q=16))
    b = split_workers(next(lm_batches(DENSE.vocab_size, N * 2, 16)), N)
    _, _, m = step(params, state, b, KEY)
    assert m["loss_per_worker"].shape == (N,)
    assert float(m["agg_grad_norm"]) > 0


def test_train_steps_compile_once():
    """C204 regression: both trainers lower exactly once per config and
    every subsequent identical-shape call hits the jit trace cache."""
    from repro.analysis.jaxpr_audit import audit_single_compile
    rcfg = RobustConfig(n_workers=N, f=F, gar="multi_bulyan")
    params = MD.init_model(KEY, DENSE)
    opt = sgd(momentum=0.9)
    state = init_train_state(opt, params)
    it = lm_batches(DENSE.vocab_size, N * 2, 16, seed=3)
    # batches are materialised up front: the data generator is eager and
    # its compiles must not count against the step's budget
    batches = [split_workers(next(it), N) for _ in range(6)]
    makers = {
        "stacked": make_train_step(DENSE, rcfg, opt, constant(0.05),
                                   chunk_q=16, attack="sign_flip"),
        "streaming": make_streaming_train_step(
            DENSE, rcfg, opt, constant(0.05), scope="block", chunk_q=16,
            attack="sign_flip"),
    }
    for label, fn in makers.items():
        step = jax.jit(fn)
        feed = iter(list(batches))

        def make_args(_feed=feed):
            return (params, state, next(_feed), KEY)

        res = audit_single_compile(step, make_args, label=label)
        assert res.ok, res.violations
