"""repro.hier — hierarchical aggregation: parity, budgets, dispatch, sim.

The load-bearing acceptance test is *bitwise* flat parity: with g >= n the
hierarchy degenerates to a single group and must reproduce
``core.api.aggregate_tree`` exactly (same stats, same plan, same apply),
on the PR-2 edge grid (n not divisible by 8, d not divisible by 128).
"""
from __future__ import annotations

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, theory
from repro.hier import GroupConfig, hier_aggregate_tree

KEY = jax.random.key(7)


def _tree(n: int, key=KEY):
    """Two-leaf tree on the PR-2 edge shapes (d not divisible by 128)."""
    ka, kb = jax.random.split(key)
    return {"a": jax.random.normal(ka, (n, 100), jnp.float32),
            "b": jax.random.normal(kb, (n, 257), jnp.float32)}


# ========================================================================
# f-budget arithmetic (core.theory.split_f_budget)
# ========================================================================
def test_group_sizes_balanced_contiguous():
    assert theory.group_sizes(11, 4) == (4, 4, 3)
    assert theory.group_sizes(64, 16) == (16, 16, 16, 16)
    assert theory.group_sizes(5, 8) == (5,)
    assert sum(theory.group_sizes(2048, 64)) == 2048


def test_split_f_budget_derivation():
    b = theory.split_f_budget(256, 7, 16)
    assert (b.n_groups, b.f_inner, b.f_outer) == (16, 3, 1)
    assert b.covers()
    # g >= n: single group, flat budget, no outer level
    b = theory.split_f_budget(11, 2, 11)
    assert (b.n_groups, b.f_inner, b.f_outer) == (1, 2, 0)
    assert b.bounds() == ((0, 11),)


def test_split_f_budget_rejects_infeasible_levels():
    # derived f_outer=1 but only 3 groups: bulyan outer needs 4f+3 = 7
    with pytest.raises(ValueError, match="outer.*requires n >="):
        theory.split_f_budget(12, 1, 4)
    # inner override past the group size
    with pytest.raises(ValueError, match="inner.*requires n >="):
        theory.split_f_budget(64, 7, 16, f_inner=5)


def test_split_f_budget_enforce_coverage():
    with pytest.raises(ValueError, match="does not cover contract"):
        theory.split_f_budget(21, 7, 7, f_inner=1, f_outer=0)
    b = theory.split_f_budget(21, 7, 7, f_inner=1, f_outer=0,
                              enforce=False)
    assert not b.covers()
    assert b.capturable_groups() == 3


def test_group_config_from_spec():
    gc = GroupConfig.from_spec("g=64")
    assert (gc.g, gc.rule) == (64, "multi_bulyan")
    gc = GroupConfig.from_spec(
        "g=7,rule=multi_krum,outer_rule=krum,f_inner=1,enforce=0")
    assert gc == GroupConfig(g=7, rule="multi_krum", outer_rule="krum",
                             f_inner=1, enforce_budget=False)
    with pytest.raises(ValueError, match="needs g="):
        GroupConfig.from_spec("rule=krum")
    with pytest.raises(ValueError, match="unknown --hier key"):
        GroupConfig.from_spec("g=4,zap=1")


# ========================================================================
# g >= n degenerate case: bitwise-identical to the flat rule
# ========================================================================
@pytest.mark.parametrize("rule", ["multi_bulyan", "multi_krum"])
@pytest.mark.parametrize("n,f", [(7, 1), (11, 2), (15, 3), (12, 2)])
def test_single_group_bitwise_flat(rule, n, f):
    grads = _tree(n, jax.random.fold_in(KEY, n))
    flat = api.aggregate_tree(grads, f, name=rule)
    agg, plan, info = hier_aggregate_tree(
        grads, f, GroupConfig(g=n, rule=rule))
    assert plan.outer is None and plan.n_groups == 1
    for k in flat:
        np.testing.assert_array_equal(np.asarray(flat[k]),
                                      np.asarray(agg[k]),
                                      err_msg=f"{rule} n={n} leaf {k}")
    # telemetry degenerates too: group_selection is the trivial simplex
    d = plan.diagnostics(info["inner_stats"])
    np.testing.assert_array_equal(np.asarray(d["group_selection"]), [1.0])


def test_single_group_bitwise_flat_under_jit():
    grads = _tree(11)
    flat = jax.jit(lambda g: api.aggregate_tree(g, 2, name="multi_bulyan"))(
        grads)
    hier = jax.jit(lambda g: hier_aggregate_tree(
        g, 2, GroupConfig(g=11))[0])(grads)
    for k in flat:
        np.testing.assert_array_equal(np.asarray(flat[k]),
                                      np.asarray(hier[k]))


# ========================================================================
# multi-group semantics
# ========================================================================
def test_group_permutation_invariance():
    # 7 groups of 7 with a robust outer (f_outer=1): permuting whole
    # groups permutes the outer level's inputs, which the rule is
    # invariant to
    n, f, g = 49, 3, 7
    grads = _tree(n)
    cfg = GroupConfig(g=g)
    agg, plan, _ = hier_aggregate_tree(grads, f, cfg)
    assert (plan.f_inner, plan.f_outer) == (1, 1)
    perm = np.array([3, 0, 6, 1, 5, 2, 4])
    rows = np.concatenate([np.arange(k * g, (k + 1) * g) for k in perm])
    permuted = jax.tree.map(lambda x: x[rows], grads)
    agg_p, _, _ = hier_aggregate_tree(permuted, f, cfg)
    for k in agg:
        np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(agg_p[k]),
                                   rtol=2e-5, atol=2e-6)


def test_selection_weights_convex_over_workers():
    grads = _tree(49)
    _, plan, info = hier_aggregate_tree(grads, 3, GroupConfig(g=7))
    sel = np.asarray(plan.selection_weights())
    assert sel.shape == (49,)
    assert np.all(sel >= 0)
    np.testing.assert_allclose(sel.sum(), 1.0, rtol=1e-5)
    d = plan.diagnostics(info["inner_stats"])
    assert d["score_spectrum"].shape == (49,)
    assert np.asarray(d["group_selection"]).shape == (7,)


def test_poisoned_subtree_rejected_by_robust_outer():
    # all 7 traitors in group 0 (the contiguous first-rows placement);
    # inner budget deliberately under-provisioned (f_inner=1) so group 0's
    # aggregate goes byzantine — the krum outer over 7 groups must reject
    # it and route zero selection mass to group 0
    n, f, g = 49, 7, 7
    grads = _tree(n)
    grads = jax.tree.map(lambda x: x.at[:f].set(x[:f] + 50.0), grads)
    cfg = GroupConfig(g=g, f_inner=1, f_outer=1, outer_rule="krum",
                      enforce_budget=False)
    _, plan, info = hier_aggregate_tree(grads, f, cfg)
    d = plan.diagnostics(info["inner_stats"])
    gsel = np.asarray(d["group_selection"])
    assert gsel[0] == pytest.approx(0.0, abs=1e-6)
    assert float(d["byz_mass"]) == pytest.approx(0.0, abs=1e-6)


def test_poisoned_subtree_captured_without_outer_robustness():
    # same under-provisioned inner budget but an averaging outer level:
    # the captured group's full 1/n_groups mass flows through
    n, f, g = 21, 7, 7
    grads = _tree(n)
    grads = jax.tree.map(lambda x: x.at[:f].set(x[:f] + 50.0), grads)
    cfg = GroupConfig(g=g, f_inner=1, f_outer=0, enforce_budget=False)
    _, plan, _ = hier_aggregate_tree(grads, f, cfg)
    d = plan.diagnostics()
    assert float(d["byz_mass"]) == pytest.approx(1 / 3, abs=0.05)


def test_budget_rejection_through_aggregate():
    grads = _tree(21)
    with pytest.raises(ValueError, match="does not cover contract"):
        hier_aggregate_tree(grads, 7, GroupConfig(g=7, f_inner=1,
                                                  f_outer=0))


def test_encoded_input_and_leader_reencode():
    from repro.comm import get_codec
    codec = get_codec("qsgd:bits=4")
    grads = _tree(21)
    enc, _ = codec.encode(grads, key=jax.random.fold_in(KEY, 1))
    agg, plan, info = hier_aggregate_tree(
        enc, 1, GroupConfig(g=7), codec=codec,
        key=jax.random.fold_in(KEY, 2))
    assert plan.n_groups == 3
    assert 0 < info["leader_wire_bytes"] < enc.wire_bytes
    # the aggregate is the decoded two-hop pipeline's output — same shapes
    assert {k: v.shape for k, v in agg.items()} == \
        {"a": (100,), "b": (257,)}


# ========================================================================
# measured-crossover dispatch (kernels.dispatch)
# ========================================================================
def test_fused_wins_measured_points():
    from repro.kernels import dispatch
    assert dispatch.fused_wins(15, 100_000)          # measured win
    # two-level kernel: the d=1e6 cell flipped from the single-level
    # era's 2x loss to a measured win — deep applies route to fused now
    assert dispatch.fused_wins(15, 1_000_000)
    assert dispatch.fused_wins(11, 1_000_000)
    # unmeasured n inherits the win frontier (no measured loss remains)
    assert dispatch.fused_wins(23, dispatch.DEFAULT_FUSED_MAX_NUMEL)
    assert not dispatch.fused_wins(23, dispatch.DEFAULT_FUSED_MAX_NUMEL + 1)


def test_load_measured_rebuilds_table(tmp_path):
    from repro.kernels import dispatch
    saved = dict(dispatch.MEASURED_POINTS)
    payload = {"results": {
        "multi_bulyan[fused]": {"n=9,d=100": 1.0, "n=9,d=10000": 9.0},
        "multi_bulyan[xla]": {"n=9,d=100": 2.0, "n=9,d=10000": 3.0},
    }}
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(payload))
    try:
        dispatch.load_measured(str(p))
        assert dispatch.MEASURED_POINTS == {9: (100, 10000)}
        assert dispatch.fused_wins(9, 999)       # geomean(100,1e4) = 1000
        assert not dispatch.fused_wins(9, 1001)
        # all-wins payload: the censored table falls back to the frontier
        p2 = tmp_path / "bench_wins.json"
        p2.write_text(json.dumps({"results": {
            "multi_bulyan[fused]": {"n=9,d=100": 1.0, "n=9,d=10000": 2.0},
            "multi_bulyan[xla]": {"n=9,d=100": 2.0, "n=9,d=10000": 3.0},
        }}))
        dispatch.load_measured(str(p2))
        assert dispatch.MEASURED_POINTS == {9: (10000, None)}
        assert dispatch.DEFAULT_FUSED_MAX_NUMEL == 10000
        assert dispatch.fused_wins(9, 10000)
        assert not dispatch.fused_wins(9, 10001)
    finally:
        dispatch.MEASURED_POINTS = saved
        dispatch.FUSED_MAX_NUMEL, dispatch.DEFAULT_FUSED_MAX_NUMEL = \
            dispatch._build_table(saved)


def test_apply_dispatch_falls_back_past_crossover(monkeypatch):
    from repro.kernels import ops as kops
    calls = []
    real = kops.fused_select

    def spy(*args, **kw):
        calls.append(args[0].shape)
        return real(*args, **kw)

    monkeypatch.setattr(kops, "fused_select", spy)
    small = jax.random.normal(KEY, (11, 100), jnp.float32)
    api.aggregate_tree({"w": small}, 2, name="multi_bulyan",
                       use_pallas=True)
    assert calls, "below the crossover the fused kernel must be used"
    calls.clear()
    from repro.kernels import dispatch
    # pin a small threshold: the real refreshed table routes everything
    # measured to fused, which would make this exercise a d > 1e6 apply
    monkeypatch.setattr(dispatch, "FUSED_MAX_NUMEL", {})
    monkeypatch.setattr(dispatch, "DEFAULT_FUSED_MAX_NUMEL", 4096)
    big_d = dispatch.DEFAULT_FUSED_MAX_NUMEL + 1
    big = jax.random.normal(KEY, (23, big_d), jnp.float32)
    api.aggregate_tree({"w": big}, 2, name="multi_bulyan", use_pallas=True)
    assert not calls, "past the crossover the XLA substrate must be taken"
    # "force" pins the kernel regardless of the table
    api.aggregate_tree({"w": big}, 2, name="multi_bulyan", use_pallas=True,
                       fused="force")
    assert calls


# ========================================================================
# campaign-level acceptance (sim integration)
# ========================================================================
def test_hier_campaign_poisoned_subtree():
    from repro.sim import AttackPhase, AttackSchedule, Scenario, \
        run_campaign
    sched = AttackSchedule((
        AttackPhase(steps=2, attack="none"),
        AttackPhase(steps=2, attack="little_is_enough:z=4.0")))
    sc = Scenario(name="hier-capture-test", schedule=sched, n_workers=21,
                  f=7, gar="multi_bulyan", hier_g=7, hier_f_inner=1,
                  hier_f_outer=0, hier_enforce=False, seq=32,
                  per_worker_batch=1)
    r = run_campaign(sc)
    assert r.trace["group_selection"].shape == (4, 3)
    assert r.trace["group_suspicion"].shape == (4, 3)
    # whole-group collusion through an under-provisioned inner budget:
    # group 0's full averaging share flows into the update
    assert float(np.mean(r.trace["byz_mass"][2:])) > 0.15
    ph = r.summary["phases"][1]
    assert len(ph["group_selection_mean"]) == 3
    assert len(ph["group_suspicion_last"]) == 3


def test_scenario_rejects_bad_hier():
    from repro.sim import AttackPhase, AttackSchedule, Scenario
    sched = AttackSchedule((AttackPhase(steps=1),))
    with pytest.raises(ValueError, match="does not cover contract"):
        Scenario(name="x", schedule=sched, n_workers=21, f=7, hier_g=7,
                 hier_f_inner=1, hier_f_outer=0)
    with pytest.raises(ValueError, match="error-feedback"):
        Scenario(name="x", schedule=sched, n_workers=21, f=1, hier_g=7,
                 codec="topk:frac=0.1,ef=1")
